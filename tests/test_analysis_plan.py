"""The `repro.analysis` pass architecture: plans, passes, combinators.

Covers the PR-4 satellites: `BitwidthPlan` round-trip serialization, pass
memoization hits, the soundness-nesting invariant as a plan-level check,
the `Select` abstract-evaluation fix, the `types_from_alpha` clamp
warning, per-phase alpha columns on the extended DUS benchmark, and the
legacy entry points as byte-identical shims over one-pass plans.
"""
import json
import math
import warnings

import numpy as np
import pytest

from repro.analysis import (BitwidthPlan, MEMO_STATS, PlanNestingError,
                            ProfilePass, SmtPass, clear_memo, meet,
                            pipeline_content_hash, refine, run_plan,
                            widen_to)
from repro.core.interval import Interval
from repro.core.range_analysis import (StageRange, analyze, analyze_direct,
                                       static_cmp)
from repro.dsl.builder import PipelineBuilder, absv, ite
from repro.dsl.exec import run_abstract, run_fixed, run_float
from repro.pipelines import dus, usm
from repro.pipelines import workflows as W
from repro.smt import SMTConfig, analyze_smt

_CFG = SMTConfig(time_budget_s=5.0)


def _profile_images(n=2, shape=(12, 12)):
    rng = np.random.default_rng(7)
    return [rng.integers(0, 256, size=shape).astype(np.float64)
            for _ in range(n)]


def _usm_plan(betas=None):
    p = usm.build()
    prof = ProfilePass(_profile_images(), params=usm.DEFAULT_PARAMS)
    return run_plan(p, ["interval", "affine", meet("interval", "affine"),
                        SmtPass(config=_CFG), prof],
                    betas=betas, default_column="smt")


# ---------------------------------------------------------------------------
# BitwidthPlan round-trip serialization
# ---------------------------------------------------------------------------

def test_plan_roundtrip_serialization():
    plan = _usm_plan(betas={"masked": 4})
    text = plan.to_json()
    back = BitwidthPlan.from_json(text)
    assert back == plan
    # stable text form: serializing the round-tripped plan is byte-identical
    assert back.to_json() == text
    # provenance and betas survive
    assert back.provenance["smt"].pass_name == "smt"
    assert back.betas == {"masked": 4}


def test_plan_phase_columns_roundtrip():
    p = dus.build_extended()
    plan = run_plan(p, ["interval", SmtPass(config=_CFG, phases=True)],
                    default_column="smt")
    assert plan.phases["smt"], "phase-split stages expected on dus_ext"
    back = BitwidthPlan.from_json(plan.to_json())
    assert back.phases == plan.phases
    assert back.to_json() == plan.to_json()


# ---------------------------------------------------------------------------
# memoization
# ---------------------------------------------------------------------------

def test_run_plan_memoizes_per_pass():
    clear_memo()
    p = usm.build()
    run_plan(p, ["interval", "affine"])
    misses = MEMO_STATS["misses"]
    assert MEMO_STATS["hits"] == 0 and misses == 2
    # identical plan on an identical (re-built) pipeline: all hits
    run_plan(usm.build(), ["interval", "affine"])
    assert MEMO_STATS["hits"] == 2 and MEMO_STATS["misses"] == misses


def test_combinator_shares_subpass_results():
    clear_memo()
    p = usm.build()
    # meet() runs interval+affine through ctx.run; requesting the plain
    # columns in the same plan must not re-execute them
    run_plan(p, ["interval", "affine", meet("interval", "affine")])
    assert MEMO_STATS["misses"] == 3  # interval, affine, meet itself
    assert MEMO_STATS["hits"] == 2    # meet's two sub-pass lookups


def test_content_hash_tracks_mutation():
    p = usm.build()
    h0 = pipeline_content_hash(p)
    assert pipeline_content_hash(usm.build()) == h0
    p.params["weight"] = Interval(0.0, 2.0)
    assert pipeline_content_hash(p) != h0


# ---------------------------------------------------------------------------
# soundness nesting as a plan-level check
# ---------------------------------------------------------------------------

def test_plan_nesting_invariant_profile_smt_meet():
    plan = _usm_plan()
    assert plan.check_nesting(["profile", "smt", "meet(interval,affine)"])
    assert plan.check_nesting(["smt", "interval"])


def test_plan_nesting_violation_raises():
    plan = _usm_plan()
    # tamper: shrink the interval column below the smt column
    plan.columns["interval"]["sharpen"] = StageRange(
        range=Interval(0.0, 1.0), alpha=1, signed=False)
    with pytest.raises(PlanNestingError, match="sharpen"):
        plan.check_nesting(["smt", "interval"])


# ---------------------------------------------------------------------------
# satellite: Select abstract evaluation (guard decided statically)
# ---------------------------------------------------------------------------

def _select_pipe(thresh: float):
    p = PipelineBuilder("selp")
    img = p.image("img", 0, 255)
    out = p.define("out", ite(img < thresh, img * 2.0, img - 300.0))
    p.output(out)
    return p.build()


@pytest.mark.parametrize("domain", ["interval", "affine", "intersect"])
def test_select_guard_decided_statically(domain):
    # guard img < 300 is always true on [0, 255]: only the then-branch range
    res = analyze(_select_pipe(300.0), domain=domain)
    assert res["out"].range.lo == 0.0 and res["out"].range.hi == 510.0
    # guard img < -1 is always false: only the else-branch range
    res = analyze(_select_pipe(-1.0), domain=domain)
    assert res["out"].range.lo == -300.0 and res["out"].range.hi == -45.0


@pytest.mark.parametrize("domain", ["interval", "affine", "intersect"])
def test_select_guard_undecided_joins(domain):
    res = analyze(_select_pipe(100.0), domain=domain)
    assert res["out"].range.lo == -300.0 and res["out"].range.hi == 510.0


def test_select_static_cmp_table():
    a, b = Interval(0.0, 1.0), Interval(2.0, 3.0)
    assert static_cmp("<", a, b) is True
    assert static_cmp(">", a, b) is False
    assert static_cmp("<=", b, a) is False
    assert static_cmp(">=", b, a) is True
    assert static_cmp("<", a, Interval(0.5, 2.0)) is None
    # boundary: touching ranges decide only the non-strict comparison
    assert static_cmp("<=", Interval(0.0, 1.0), Interval(1.0, 2.0)) is True
    assert static_cmp("<", Interval(0.0, 1.0), Interval(1.0, 2.0)) is None


def test_select_perpixel_matches_combined_enclosure():
    """The per-pixel executor decides guards pixel-wise; combined analysis
    must remain an enclosure of it (regression for the shared fix)."""
    p = _select_pipe(300.0)
    comb = analyze(p)
    per = run_abstract(p, (6, 6), "interval")
    for k in p.topo_order():
        assert comb[k].range.encloses(per[k]["range"]), k


# ---------------------------------------------------------------------------
# satellite: types_from_alpha clamp warning + plan provenance record
# ---------------------------------------------------------------------------

def test_types_from_alpha_warns_on_clamp():
    p = usm.build()
    alphas, signed = W.static_alphas(p)
    alphas = dict(alphas, blurx=0)          # synthetic zero-range stage
    with pytest.warns(RuntimeWarning, match="blurx"):
        t = W.types_from_alpha(p, alphas, signed, {})
    assert t["blurx"].alpha == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # no clamp -> no warning
        W.types_from_alpha(p, dict(alphas, blurx=8), signed, {})


def test_plan_types_records_clamp_in_provenance():
    plan = _usm_plan()
    plan.columns["smt"]["blurx"] = StageRange(
        range=Interval(0.0, 0.0), alpha=0, signed=False)
    with pytest.warns(RuntimeWarning, match="blurx"):
        t = plan.types("smt")
    assert t["blurx"].alpha == 1
    assert any("blurx" in n for n in plan.provenance["smt"].notes)
    # the note travels with the serialized plan
    back = BitwidthPlan.from_json(plan.to_json())
    assert any("blurx" in n for n in back.provenance["smt"].notes)


# ---------------------------------------------------------------------------
# combinators
# ---------------------------------------------------------------------------

def test_meet_is_sound_and_tightest():
    plan = run_plan(usm.build(), ["interval", "affine",
                                  meet("interval", "affine")])
    m = plan.columns["meet(interval,affine)"]
    ia = plan.columns["interval"]
    af = plan.columns["affine"]
    for n in m:
        assert ia[n].range.encloses(m[n].range), n
        assert af[n].range.encloses(m[n].range), n


def test_refine_clamps_input_ranges():
    p = usm.build()
    prof = ProfilePass(_profile_images(), params=usm.DEFAULT_PARAMS)
    plan = run_plan(p, ["interval", refine("interval", prof)])
    ref = plan.columns["refine(interval,profile)"]
    ia = plan.columns["interval"]
    for n in ref:
        assert ia[n].range.encloses(ref[n].range), n
    assert any("profiled input distribution" in note
               for note in plan.provenance["refine(interval,profile)"].notes)


def test_widen_to_bit_boundaries_and_budget_note():
    p = usm.build()
    plan = run_plan(p, ["interval", widen_to("interval", 9)])
    col = plan.columns["widen(interval,9)"]
    ia = plan.columns["interval"]
    for n in col:
        assert col[n].alpha == ia[n].alpha, n        # widening keeps alpha
        assert col[n].range.encloses(ia[n].range), n
        lo, hi = col[n].range.lo, col[n].range.hi
        assert float(lo).is_integer() and float(hi).is_integer()
    # sharpen (alpha 10) exceeds the 9-bit budget -> reported, not clamped
    assert any("sharpen" in note
               for note in plan.provenance["widen(interval,9)"].notes)


def test_widen_to_forwards_phase_columns():
    sub = SmtPass(config=_CFG, phases=True)
    plan = run_plan(dus.build_extended(),
                    [sub, widen_to(sub, 16, column="widened")])
    assert "resS" in plan.phases["widened"]
    _, rmap = plan.phases["widened"]["resS"]
    # the aligned phase's alpha-bit win survives widening
    assert rmap[(0, 0)].alpha == 8
    assert float(rmap[(0, 0)].range.hi).is_integer()


def test_smt_phase_split_registry_name_coexists_with_smt():
    plan = run_plan(dus.build_extended(),
                    ["smt", "smt-phase-split"])
    assert "smt" in plan.columns and "smt-phase-split" in plan.columns
    assert "resS" in plan.phases["smt-phase-split"]


def test_meet_forwards_phase_columns():
    sub = SmtPass(config=_CFG, phases=True)
    plan = run_plan(dus.build_extended(),
                    [meet(sub, "interval", column="met")])
    assert "resS" in plan.phases["met"]
    _, rmap = plan.phases["met"]["resS"]
    assert rmap[(0, 0)].alpha == 8      # per-phase win survives the meet


def test_profile_passes_with_different_runners_do_not_collide():
    imgs = _profile_images()
    default = ProfilePass(imgs, params=usm.DEFAULT_PARAMS)

    def halved_runner(image, params):
        return {k: v * 0.5
                for k, v in run_float(usm.build(), image, params).items()}

    halved = ProfilePass(imgs, runner=halved_runner,
                         params=usm.DEFAULT_PARAMS, column="profile-halved")
    assert default.key() != halved.key()
    plan = run_plan(usm.build(), [default, halved])
    a, b = plan.columns["profile"], plan.columns["profile-halved"]
    assert any(b[n].range.hi < a[n].range.hi for n in a)


# ---------------------------------------------------------------------------
# legacy entry points are byte-identical shims over one-pass plans
# ---------------------------------------------------------------------------

def test_analyze_shim_matches_direct_walk():
    for domain in ("interval", "affine", "intersect"):
        p = usm.build()
        via_shim = analyze(p, domain=domain)
        direct = analyze_direct(p, domain=domain)
        assert via_shim == direct


def test_static_alphas_shim_matches_plan():
    p = usm.build()
    alphas, signed = W.static_alphas(p)
    plan = run_plan(p, ["interval"])
    assert alphas == plan.alphas("interval")
    assert signed == plan.signed("interval")
    direct = analyze_direct(p)
    assert alphas == {n: r.alpha for n, r in direct.items()}


def test_smt_alphas_shim_matches_analyze_smt():
    p = usm.build()
    alphas, signed = W.smt_alphas(p, config=_CFG)
    direct = analyze_smt(p, config=_CFG)
    assert alphas == {n: r.alpha for n, r in direct.items()}
    assert signed == {n: r.signed for n, r in direct.items()}


def test_alpha_columns_shim_matches_plan():
    b = W.make_usm(2, 2, (16, 16))
    cols = W.alpha_columns(b, smt_config=_CFG)
    plan = run_plan(b.pipeline, ["interval", SmtPass(config=_CFG),
                                 b.profile_pass()])
    for n in b.pipeline.topo_order():
        assert cols[n]["interval"] == plan.columns["interval"][n].alpha
        assert cols[n]["smt"] == plan.columns["smt"][n].alpha
        assert cols[n]["profile_max"] == plan.columns["profile"][n].alpha
        assert cols[n]["smt_range"] == plan.columns["smt"][n].range


# ---------------------------------------------------------------------------
# per-phase alpha columns (the PR-3 wins, now representable) + execution
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dus_ext_plan():
    return run_plan(dus.build_extended(),
                    ["interval", SmtPass(config=_CFG, phases=True)],
                    betas={n: 4 for n in dus.build_extended().stages},
                    default_column="smt")


def test_phase_columns_strictly_tighter_than_union(dus_ext_plan):
    plan = dus_ext_plan
    phases = plan.phases["smt"]
    union = plan.columns["smt"]
    # every phase sub-range is enclosed by its union bound
    for stage, (lat, rmap) in phases.items():
        for res, sr in rmap.items():
            assert union[stage].range.encloses(sr.range), (stage, res)
            assert sr.alpha <= union[stage].alpha, (stage, res)
    # the sharp residual channel: the aligned phase drops a whole alpha bit
    (my, mx), rmap = phases["resS"]
    assert (my, mx) == (2, 1)
    assert union["resS"].alpha == 9
    assert rmap[(0, 0)].alpha == 8
    assert rmap[(1, 0)].alpha == 9
    # strictly tighter range on at least one phase of the plain residual too
    (_, _), res_map = phases["res"]
    assert any(sr.range.hi < union["res"].range.hi - 1.0
               for sr in res_map.values())


def test_phase_collection_does_not_move_union_bounds():
    p = dus.build_extended()
    with_phases = analyze_smt(p, config=_CFG, collect_phases={})
    without = analyze_smt(p, config=_CFG)
    assert {n: r.range for n, r in with_phases.items()} == \
        {n: r.range for n, r in without.items()}


def test_run_fixed_accepts_plan_with_phase_types(dus_ext_plan):
    plan = dus_ext_plan
    p = dus.build_extended()
    rng = np.random.default_rng(3)
    img = rng.integers(0, 256, size=(16, 16)).astype(np.float64)
    env_plan = run_fixed(p, img, plan)
    env_union = run_fixed(p, img, plan.types())
    # exact per-phase ranges: saturation never engages, so per-phase
    # datapaths are bit-identical to the union design on real data...
    for n in p.topo_order():
        np.testing.assert_allclose(env_plan[n], env_union[n], err_msg=n)
    # ...while the aligned resS phase carries one fewer integral bit
    ptypes = plan.phase_types()
    assert ptypes["resS"][1][(0, 0)].width < plan.types()["resS"].width
    # sanity: the fixed run stays close to float
    ref = run_float(p, img)
    err = np.max(np.abs(env_plan["resS"] - ref["resS"]))
    assert err < 1.0


def test_plan_executes_on_jax_backend(dus_ext_plan):
    p = dus.build_extended()
    rng = np.random.default_rng(5)
    img = rng.integers(0, 256, size=(8, 8)).astype(np.float32)
    env = run_fixed(p, img, dus_ext_plan, backend="jax")
    assert np.isfinite(np.asarray(env["resS"])).all()


def test_dus_ext_union_smt_alpha_unchanged_by_sharp_channel():
    """The added DyS/UyS/resS stages are convex/residual channels: they do
    not move any pre-existing stage's bounds (golden-table compatibility)."""
    p = dus.build_extended()
    res = analyze_smt(p, config=_CFG)
    for s in ("Dx", "Dy", "Ux", "Uy", "D5", "DyS", "UyS"):
        assert res[s].alpha == 8, s
        assert (res[s].range.lo, res[s].range.hi) == (0.0, 255.0), s
    assert res["band"].alpha == 7
    assert res["res"].alpha == 9
    assert res["resS"].alpha == 9
    assert math.isclose(res["resS"].range.hi, 255.0 * 56 / 64, rel_tol=1e-9)


# ---------------------------------------------------------------------------
# plan JSON artifact format (what benchmarks/alpha_delta.py consumes)
# ---------------------------------------------------------------------------

def test_alpha_delta_loader_reads_plan_json(tmp_path):
    from benchmarks.alpha_delta import _load
    plan = _usm_plan()
    # profile column alphas are per-pixel statistics; columns are complete
    blob = {"version": 1, "groups": {"usm": plan.to_json_dict()}}
    path = tmp_path / "plans.json"
    path.write_text(json.dumps(blob))
    loaded = _load(str(path))
    for n in plan.columns["interval"]:
        ia, sa, pa = loaded[("usm", n)]
        assert ia == plan.columns["interval"][n].alpha
        assert sa == plan.columns["smt"][n].alpha
        assert pa == plan.columns["profile"][n].alpha


# ---------------------------------------------------------------------------
# disk-backed plan cache (run_plan(cache_dir=...))
# ---------------------------------------------------------------------------

def test_disk_cache_round_trip_and_hit(tmp_path):
    from repro.analysis import DISK_CACHE_STATS
    p = usm.build()
    betas = {n: 3 for n in p.stages}
    clear_memo()
    plan = run_plan(p, ["interval"], betas=betas, cache_dir=str(tmp_path))
    assert DISK_CACHE_STATS["misses"] == 1
    assert DISK_CACHE_STATS["writes"] == 1
    files = list(tmp_path.glob("*.plan.json"))
    assert len(files) == 1
    # second run: loaded from disk, byte-identical plan, no pass executes
    clear_memo()
    plan2 = run_plan(p, ["interval"], betas=betas, cache_dir=str(tmp_path))
    assert DISK_CACHE_STATS["hits"] == 1
    assert MEMO_STATS["misses"] == 0          # nothing re-analyzed
    assert plan2.to_json() == plan.to_json()


def test_disk_cache_key_covers_passes_betas_and_content(tmp_path):
    p = usm.build()
    run_plan(p, ["interval"], cache_dir=str(tmp_path))
    run_plan(p, ["affine"], cache_dir=str(tmp_path))
    run_plan(p, ["interval"], betas={"blurx": 2}, cache_dir=str(tmp_path))
    # different pipeline content -> different file
    p2 = usm.build()
    p2.stages["masked"].stride = (2, 2)
    run_plan(p2, ["interval"], cache_dir=str(tmp_path))
    assert len(list(tmp_path.glob("*.plan.json"))) == 4


def test_disk_cache_skips_process_local_profile_runners(tmp_path):
    from repro import obs
    from repro.analysis import DISK_CACHE_STATS
    p = usm.build()
    clear_memo()
    obs.reset_warn_once()       # the skip warning is process-once now
    prof = ProfilePass(_profile_images(),
                       runner=lambda im, par: run_float(p, im, par),
                       params=usm.DEFAULT_PARAMS)
    with pytest.warns(RuntimeWarning, match="process-local"):
        run_plan(p, [prof], cache_dir=str(tmp_path))
    assert DISK_CACHE_STATS["skips"] == 1
    assert not list(tmp_path.glob("*.plan.json"))


def test_benchmark_setup_plan_cache_dir(tmp_path):
    from repro.analysis import DISK_CACHE_STATS
    setup = W.make_usm(n_train=1, n_test=1, shape=(16, 16))
    clear_memo()
    plan = setup.plan(smt_config=_CFG, cache_dir=str(tmp_path))
    assert DISK_CACHE_STATS["writes"] == 1
    clear_memo()
    plan2 = setup.plan(smt_config=_CFG, cache_dir=str(tmp_path))
    assert DISK_CACHE_STATS["hits"] == 1
    assert plan2.to_json() == plan.to_json()


# ---------------------------------------------------------------------------
# per-phase datapath pricing (cost_model + design_report)
# ---------------------------------------------------------------------------

def test_phase_mean_width_duty_cycle():
    from repro.core.cost_model import phase_mean_width
    from repro.core.fixedpoint import FixedPointType
    entry = ((2, 1), {(0, 0): FixedPointType(8, 0, True)})
    # one residue at 8 bits, the missing one at the 10-bit union
    assert phase_mean_width(entry, 10) == 9.0


def test_design_report_shows_phase_split_win(dus_ext_plan):
    rep = W.design_report(dus.build_extended(), dus_ext_plan)
    assert "fixed_phase" in rep and "phase_improvement" in rep
    imp = rep["phase_improvement"]
    # per-residue datapaths are never pricier than the union design, and
    # the resS alpha-bit split must show up as a strict win somewhere
    assert all(v >= 1.0 - 1e-12 for v in imp.values()), imp
    assert any(v > 1.0 for v in imp.values()), imp
    # union-design entries are untouched (back-compat)
    assert rep["fixed"].power_proxy >= rep["fixed_phase"].power_proxy


def test_design_cost_phase_types_reduce_tpu_bytes():
    from repro.core import cost_model
    from repro.core.fixedpoint import FixedPointType
    p = dus.build_extended()
    types = {n: FixedPointType(10, 0, True) for n in p.stages}
    ph = {"resS": ((2, 1), {(0, 0): FixedPointType(8, 0, True),
                           (1, 0): FixedPointType(8, 0, True)})}
    base = cost_model.design_cost(p, types)
    split = cost_model.design_cost(p, types, phase_types=ph)
    assert split.bytes_per_pixel_tpu < base.bytes_per_pixel_tpu
