"""Unit + property tests for the interval domain (Algorithm 1 transfer fns)."""
import math

import pytest
from _hyp_compat import given, settings, st

from repro.core.interval import Interval, stencil_range

finite = st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                   allow_infinity=False)


def ivs():
    return st.tuples(finite, finite).map(lambda t: Interval(min(t), max(t)))


def pick(iv, t):
    """A sample inside iv (clamped against float rounding)."""
    return min(max(iv.lo + t * (iv.hi - iv.lo), iv.lo), iv.hi)


# -- soundness: concrete results always inside abstract results -----------------

@given(ivs(), ivs(), st.floats(0, 1), st.floats(0, 1))
@settings(max_examples=200)
def test_add_sub_mul_sound(a, b, ta, tb):
    x = pick(a, ta)
    y = pick(b, tb)
    assert (a + b).contains(x + y)
    assert (a - b).contains(x - y)
    # mul can overflow float precision slightly; widen tolerance via contains
    assert (a * b).contains(x * y) or abs(x * y) > 1e11


@given(ivs(), ivs(), st.floats(0, 1), st.floats(0, 1))
@settings(max_examples=200)
def test_div_sound(a, b, ta, tb):
    x = pick(a, ta)
    y = pick(b, tb)
    r = a / b
    if b.lo <= 0.0 <= b.hi:
        assert math.isinf(r.lo) and math.isinf(r.hi)
    else:
        q = x / y
        if not math.isfinite(q):
            return                       # float overflow, not an interval issue
        tol = 1e-9 * (1.0 + abs(q))     # last-ulp slack for large quotients
        assert r.lo - tol <= q <= r.hi + tol


@given(ivs(), st.integers(0, 6), st.floats(0, 1))
@settings(max_examples=200)
def test_pow_sound(a, n, t):
    x = pick(a, t)
    got = a ** n
    want = x ** n
    if abs(want) < 1e30:
        assert got.contains(want)


@given(ivs(), st.floats(0, 1))
@settings(max_examples=100)
def test_abs_sqrt_sound(a, t):
    x = pick(a, t)
    assert a.abs().contains(abs(x))
    if x >= 0:
        assert a.sqrt().contains(math.sqrt(x))


def test_even_pow_tighter_than_mul():
    # the paper's x*x vs x**2 example (§IV-B)
    x = Interval(-2, 2)
    assert (x * x).lo == -4 and (x * x).hi == 4
    assert (x ** 2).lo == 0 and (x ** 2).hi == 4


def test_div_by_zero_interval_is_top():
    assert (Interval(1, 2) / Interval(-1, 1)).lo == -math.inf


def test_paper_overestimation_example():
    # §III-C: x in [5,10] -> interval says x - x = [-5, 5]
    x = Interval(5, 10)
    r = x - x
    assert (r.lo, r.hi) == (-5, 5)


def test_sobel_range_is_85():
    # Table II: 1/12 Sobel on [0,255] -> [-85, 85]
    r = stencil_range(Interval(0, 255),
                      [[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], scale=1 / 12)
    assert (r.lo, r.hi) == (-85, 85)


def test_join_and_contains():
    assert Interval(0, 1).join(Interval(5, 6)).encloses(Interval(2, 3))
    assert Interval(0, 2).contains(1.5)
