"""AutoQuant (paper technique on LMs): range analysis, calibration, search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.batches import make_batch
from repro.models.registry import get_model
from repro.quant import autoquant as aq
from repro.quant import calibrate, range_lm
from repro.quant.qtypes import (dequantize_symmetric, fake_quant_ste,
                                quantize_symmetric)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen3-4b")
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batches = [make_batch(cfg, 2, 16, seed=s) for s in range(2)]
    return cfg, m, params, batches


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    for bits in (8, 4, 2):
        q, s = quantize_symmetric(x, bits=bits, axis=-1)
        back = dequantize_symmetric(q, s)
        step = np.asarray(s)
        assert float(jnp.max(jnp.abs(back - x))) <= float(step.max()) * 0.5001


def test_ste_gradient_is_identity():
    x = jnp.linspace(-1, 1, 32)
    g = jax.grad(lambda v: jnp.sum(fake_quant_ste(v, bits=4)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(32), atol=1e-6)


def test_static_ranges_sound_vs_observed(qwen):
    """Paper soundness invariant: static interval >= observed activations."""
    cfg, m, params, batches = qwen
    stat = range_lm.static_ranges(params, cfg)
    obs = calibrate.activation_stats(m, params, batches)
    assert stat["logits"].encloses(obs["logits"])
    # and the gap is large (the deep-pipeline blow-up, Table IX analogue)
    assert stat["logits"].width > 10 * obs["logits"].width


def test_static_alpha_blowup_with_depth():
    import dataclasses
    cfg2 = get_smoke_config("qwen3-4b")
    cfg8 = dataclasses.replace(cfg2, n_layers=8)
    m2, m8 = get_model(cfg2), get_model(cfg8)
    p2 = m2.init_params(jax.random.PRNGKey(1))
    p8 = m8.init_params(jax.random.PRNGKey(1))
    a2 = range_lm.static_alpha_table(p2, cfg2)
    a8 = range_lm.static_alpha_table(p8, cfg8)
    assert a8["resid_final"] >= a2["resid_final"]


def test_weight_stats_classes(qwen):
    cfg, m, params, _ = qwen
    stats = calibrate.weight_stats(params)
    assert set(stats) >= {"embed", "attn", "mlp", "unembed"}
    assert all(s["absmax"] > 0 for s in stats.values())


def test_fake_quant_params_only_touches_selected(qwen):
    cfg, m, params, _ = qwen
    qp = aq.fake_quant_params(params, {"mlp": 4})
    # mlp weights changed, attention untouched
    assert not np.allclose(np.asarray(qp["blocks"]["mlp"]["w_gate"]),
                           np.asarray(params["blocks"]["mlp"]["w_gate"]))
    np.testing.assert_array_equal(np.asarray(qp["blocks"]["attn"]["wq"]),
                                  np.asarray(params["blocks"]["attn"]["wq"]))


def test_autoquant_end_to_end(qwen):
    """The full paper loop on an LM: few passes, quality target met."""
    cfg, m, params, batches = qwen
    res = aq.autoquant(m, params, batches, target_agreement=0.95)
    assert res.quality >= 0.95
    assert res.profile_passes <= 40          # few passes (paper's point)
    assert all(aq.MIN_BITS <= b <= aq.MAX_BITS for b in res.bits.values())
    assert res.bytes_ratio < 1.0             # actually smaller than bf16


def test_int8_weights_preserve_top1(qwen):
    cfg, m, params, batches = qwen
    qp = aq.fake_quant_params(params, {c: 8 for c in
                                       calibrate.REVERSE_TOPO_CLASSES})
    ref = m.forward(params, batches[0])
    test = m.forward(qp, batches[0])
    assert aq.token_agreement(ref, test) >= 0.95
