"""Guarded `hypothesis` import for test modules that mix property tests with
plain unit tests.

    from _hyp_compat import given, settings, st

When hypothesis is installed this re-exports the real API unchanged.  When it
is absent (it is an optional dev dependency, see requirements-dev.txt), the
decorators degrade to runtime-skip stubs so the plain tests in the same
module still collect and run.  `test_property_fuzz.py` is hypothesis-only and
is instead dropped wholesale via `collect_ignore` in conftest.py.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StubStrategy:
        """Absorbs any strategy construction (st.floats(...).map(...) etc.)."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _StubStrategy()

    def given(*_a, **_k):
        def deco(fn):
            # zero-arg replacement: pytest must not see the property's
            # parameters, or it would try to resolve them as fixtures
            def _skipped():
                pytest.skip("hypothesis not installed")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco
