"""Rate-island partitioning + narrow datapath re-election.

Two contracts land here (docs/execution_backends.md):

  * **Rate islands** — `partition_islands` cuts any lowered DAG into
    maximal band-schedulable subgraphs; each island runs fully fused
    through the pallas line-buffer kernel and islands stitch through
    materialized HBM boundary buffers.  Every benchmark (of_pyramid
    included) must lower this way with ZERO jnp fallbacks, bit-for-bit
    against the `run_fixed` numpy oracle — including rate-inexact shapes
    the old whole-DAG scheduler rejected with `LoweringError`.
  * **Narrow datapath re-election** — `lower(..., datapath="narrow")`
    re-elects int32/f32-first carriers; no int64 carrier or f64 expr
    stage may survive without a recorded justification, elections land
    in `BitwidthPlan` provenance, and the re-elected program stays
    bit-identical to the oracle on both lowered backends.
"""
import warnings
from fractions import Fraction

import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core.cost_model import design_cost, lowered_datapaths
from repro.core.fixedpoint import FixedPointType
from repro.core.graph import Pipeline, Stage, stencil_expr
from repro.core.range_analysis import analyze
from repro.dsl.exec import run_fixed
from repro.lowering import (LoweringError, build_schedule, compile_backend,
                            lower, partition_islands)
from repro.lowering.islands import _ext_inputs
from repro.lowering.schedule import stage_shapes
from repro.pipelines import dus, hcd, optical_flow, usm
from repro.pipelines import workflows as W
from test_lowering import _gen_pipe, _img, _types_for

GATE = [
    ("usm", usm.build, dict(usm.DEFAULT_PARAMS), 1, (48, 48)),
    ("hcd", hcd.build, {}, 1, (48, 48)),
    ("dus_ext", dus.build_extended, {}, 1, (48, 48)),
    ("of_pyramid", lambda: optical_flow.build_pyramid(1), {}, 2, (40, 40)),
]


def _inputs_for(pipe, shape, seed, n_in):
    imgs = tuple(_img(shape, seed=seed + i) for i in range(n_in))
    return imgs[0] if n_in == 1 else imgs


# ---------------------------------------------------------------------------
# the island gate: every benchmark fuses, bit-exact, no fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,build,params,n_in,shape",
                         GATE, ids=[g[0] for g in GATE])
def test_island_gate_fused_and_bit_exact(name, build, params, n_in, shape):
    pipe = build()
    types = _types_for(pipe)
    lp = lower(pipe, types, params=params)
    plan = partition_islands(lp, shape)
    assert plan.fully_fused, f"{name}: jnp fallback crept back in"
    assert plan.islands, name
    covered = [s for isl in plan.islands for s in isl.stages]
    compute = [n for n in lp.order if not lp.stages[n].stage.is_input]
    assert sorted(covered) == sorted(compute)       # exact cover, no dupes
    img = _inputs_for(pipe, shape, 31, n_in)
    oracle = run_fixed(pipe, img, types, params)
    outs = compile_backend(lp, "pallas")(img)
    for stage in pipe.outputs:
        np.testing.assert_array_equal(
            np.asarray(oracle[stage]), outs[stage],
            err_msg=f"{name}/{stage}: stitched pallas != oracle")


def test_rate_inexact_shape_partitions_and_matches_oracle():
    """dus at 47 rows: the whole-DAG scheduler rejects it (odd height
    under stride 2), the partitioner must cut islands instead — and the
    single-tile escape hatch must edge-replicate exactly like the oracle
    (regression: the tap gather used to read out of the parent band at
    the image edges)."""
    pipe = dus.build()
    types = _types_for(pipe)
    lp = lower(pipe, types)
    with pytest.raises(LoweringError):
        build_schedule(lp, (47, 48))
    plan = partition_islands(lp, (47, 48))
    assert len(plan.islands) > 1
    img = _img((47, 48), seed=3)
    oracle = run_fixed(pipe, img, types)
    outs = compile_backend(lp, "pallas")(img)
    for stage in pipe.outputs:
        np.testing.assert_array_equal(np.asarray(oracle[stage]),
                                      outs[stage], err_msg=stage)


def test_islands_false_keeps_the_raising_contract():
    pipe = dus.build()
    lp = lower(pipe, _types_for(pipe))
    run = compile_backend(lp, "pallas", islands=False)
    with pytest.raises(LoweringError):
        run(_img((47, 48), seed=4))


def test_multi_island_boundaries_are_oracle_exact():
    """Rate-inexact dus with every stage requested: boundary buffers the
    stitching materializes must hold exactly the oracle's stage values
    (stored-representation containers, not rounded copies)."""
    pipe = dus.build()
    types = _types_for(pipe)
    lp = lower(pipe, types)
    allstages = [n for n in pipe.topo_order()
                 if not pipe.stages[n].is_input]
    plan = partition_islands(lp, (47, 48), outputs=allstages)
    assert len(plan.islands) > 1
    assert any(i.single_tile for i in plan.islands)
    img = _img((47, 48), seed=19)
    oracle = run_fixed(pipe, img, types)
    outs = compile_backend(lp, "pallas", outputs=allstages)(img)
    for stage in allstages:
        np.testing.assert_array_equal(np.asarray(oracle[stage]),
                                      outs[stage], err_msg=stage)


def test_explicit_tile_rows_is_a_whole_program_contract():
    """`tile_rows` pins the historical whole-DAG schedule: honored when
    feasible, `LoweringError` (not a silent partition) when not."""
    pipe = hcd.build()
    lp = lower(pipe, _types_for(pipe))
    plan = partition_islands(lp, (48, 48), tile_rows=8)
    assert plan.fully_fused and plan.islands[0].schedule.grid == 6
    with pytest.raises(LoweringError):
        partition_islands(lp, (48, 48), tile_rows=5)    # 5 does not tile 48


# ---------------------------------------------------------------------------
# partitioner fuzz: coverage + schedule equivalence
# ---------------------------------------------------------------------------

@st.composite
def island_pipelines(draw):
    return _gen_pipe("fuzz_islands",
                     lambda n: draw(st.integers(0, n - 1)),
                     lambda lo, hi: draw(st.floats(lo, hi)))


def _fuzz_types(pipe):
    res = analyze(pipe)
    if any(np.isinf(r.range.hi) or r.alpha > 24 for r in res.values()):
        return None
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return {n: FixedPointType(alpha=max(r.alpha, 1), beta=4,
                                  signed=r.signed)
                for n, r in res.items()}


# heights 18/22 are divisible by 2 but not 4+ (chained decimation goes
# rate-inexact) and 47 is odd (any decimation does), so the fuzz actually
# reaches multi-island partitions instead of only the whole-DAG fast path
FUZZ_HEIGHTS = (16, 18, 22, 24, 47)


@given(island_pipelines(), st.sampled_from(FUZZ_HEIGHTS),
       st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_F_partition_covers_with_rate_uniform_islands(pipe, rows, seed):
    types = _fuzz_types(pipe)
    if types is None:
        return
    shape = (rows, 16)
    lp = lower(pipe, types)
    plan = partition_islands(lp, shape)
    shapes = stage_shapes(lp, shape)
    compute = [n for n in lp.order if not lp.stages[n].stage.is_input]
    covered = [s for isl in plan.islands for s in isl.stages]
    assert sorted(covered) == sorted(compute)
    for isl in plan.islands:
        # contiguous in topo order, rate anchored at the first stage
        assert isl.rate == Fraction(shapes[isl.stages[0]][0], shape[0])
        assert isl.inputs == _ext_inputs(lp, isl.stages)
        sched = isl.schedule
        for n in isl.stages:
            ss = sched.stages[n]
            assert ss.H == shapes[n][0], n
            assert sched.grid * ss.step == ss.H, n      # exact row cover
            assert ss.lo <= 0 < ss.hi, n
        # island outputs really are consumed outside (or pipeline outputs)
        inside = set(isl.stages)
        for out in isl.outputs:
            ext_use = any(out in lp.stages[c].stage.inputs
                          for c in compute if c not in inside)
            assert ext_use or out in plan.outputs


@given(island_pipelines(), st.sampled_from(FUZZ_HEIGHTS),
       st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_F_stitched_pallas_matches_jnp_and_oracle(pipe, rows, seed):
    types = _fuzz_types(pipe)
    if types is None:
        return
    img = _img((rows, 16), seed=seed)
    oracle = run_fixed(pipe, img, types)
    lp = lower(pipe, types)
    env = compile_backend(lp, "jnp", outputs=list(pipe.stages))(img)
    outs = compile_backend(lp, "pallas")(img)       # never raises now
    for stage in outs:
        np.testing.assert_array_equal(np.asarray(oracle[stage]),
                                      outs[stage], err_msg=stage)
        np.testing.assert_array_equal(env[stage], outs[stage],
                                      err_msg=stage)


@given(island_pipelines())
@settings(max_examples=15, deadline=None)
def test_F_single_island_schedule_equals_build_schedule(pipe):
    """When the whole DAG band-schedules, the island path must reproduce
    the historical schedule exactly (same bands, same grid)."""
    types = _fuzz_types(pipe)
    if types is None:
        return
    lp = lower(pipe, types)
    try:
        whole = build_schedule(lp, (16, 16))
    except LoweringError:
        return
    plan = partition_islands(lp, (16, 16))
    assert len(plan.islands) == 1
    isl = plan.islands[0]
    sched = isl.schedule
    assert sched.grid == whole.grid
    for n, ss in whole.stages.items():
        got = sched.stages[n]
        assert (got.step, got.lo, got.hi, got.H, got.W) == \
            (ss.step, ss.lo, ss.hi, ss.H, ss.W), n


# ---------------------------------------------------------------------------
# narrow datapath re-election
# ---------------------------------------------------------------------------

NARROW = [(g[0], g[1], g[2], g[3], g[4]) for g in GATE]


@pytest.mark.parametrize("name,build,params,n_in,shape",
                         NARROW, ids=[g[0] for g in NARROW])
def test_narrow_elections_justified_and_bit_exact(name, build, params,
                                                  n_in, shape):
    pipe = build()
    types = _types_for(pipe)
    lp = lower(pipe, types, params=params, datapath="narrow")
    assert lp.datapath == "narrow"
    for n, ls in lp.stages.items():
        if ls.stage.is_input:
            continue
        if ls.kind == "intlinear" and ls.carrier == "int64":
            assert ls.election.startswith("int64 kept:"), \
                f"{name}/{n}: unjustified int64 carrier"
        if ls.kind == "expr" and ls.expr_dtype == "f64" \
                and not ls.store_float and ls.phase is None:
            assert ls.election.startswith("f64 kept:"), \
                f"{name}/{n}: unjustified f64 expr datapath"
    img = _inputs_for(pipe, shape, 41, n_in)
    oracle = run_fixed(pipe, img, types, params)
    for backend in ("jnp", "pallas"):
        run = compile_backend(lp, backend)
        outs = run(img)
        for stage in pipe.outputs:
            np.testing.assert_array_equal(
                np.asarray(oracle[stage]), outs[stage],
                err_msg=f"{name}/{stage}/{backend} (narrow)")


def test_narrow_demotes_to_f32_under_proof():
    """hcd's product stages fit the 24-bit-mantissa exactness proof at
    8-bit inputs — they must demote to f32 and still match the oracle."""
    pipe = hcd.build()
    types = _types_for(pipe)
    lp = lower(pipe, types, datapath="narrow")
    demoted = [n for n, ls in lp.stages.items()
               if ls.kind == "expr" and ls.expr_dtype == "f32"]
    assert demoted, "no stage demoted to f32 on hcd"
    assert all(lp.stages[n].election == "f32" for n in demoted)


def _wide_acc_pipe(taps: int):
    pipe = Pipeline("wideacc")
    pipe.add_stage(Stage(name="img", expr=None, is_input=True))
    pipe.add_stage(Stage(
        name="box",
        expr=stencil_expr("img", [[1.0]] * taps, scale=41.0 / 256.0),
        inputs=("img",)))
    pipe.mark_output("box")
    types = {"img": FixedPointType(alpha=27, beta=0, signed=False),
             "box": FixedPointType(alpha=27, beta=0, signed=False)}
    return pipe, types


def test_narrow_int32pair_split_is_bit_exact():
    """An accumulator bound past INT32_BUDGET splits into an int32 pair
    with one widening combine — bit-identical to the int64 carrier."""
    pipe, types = _wide_acc_pipe(taps=9)     # 9 * 2^27 > 2^30: real split
    img = np.random.default_rng(5).integers(
        0, 1 << 27, (48, 48)).astype(np.float64)
    oracle = run_fixed(pipe, img, types)
    exact = lower(pipe, types)
    narrow = lower(pipe, types, datapath="narrow")
    assert exact.stages["box"].carrier == "int64"
    ls = narrow.stages["box"]
    assert ls.carrier == "int32pair"
    assert ls.election.startswith("int32pair:")
    for lp in (exact, narrow):
        for backend in ("jnp", "pallas"):
            outs = compile_backend(lp, backend)(img)
            np.testing.assert_array_equal(
                np.asarray(oracle["box"]), outs["box"],
                err_msg=f"{lp.datapath}/{backend}")


def test_narrow_elections_recorded_in_plan_provenance():
    from repro.analysis import run_plan
    pipe = hcd.build()
    plan = run_plan(pipe, ["interval"],
                    betas={n: 4 for n in pipe.stages})
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        lower(pipe, plan, datapath="narrow")
    notes = plan.provenance[plan.default_column].notes
    assert any(n.startswith("datapath[narrow]") for n in notes)
    kept = [n for n in notes if "kept:" in n]
    assert kept, "per-stage justification lines missing from provenance"
    # round-trips through the stable JSON form
    from repro.analysis import BitwidthPlan
    again = BitwidthPlan.from_json(plan.to_json())
    assert notes == again.provenance[again.default_column].notes


def test_narrow_prices_cheaper_in_cost_model():
    pipe = hcd.build()
    types = _types_for(pipe)
    base = design_cost(pipe, types)
    ce = design_cost(pipe, types,
                     datapaths=lowered_datapaths(lower(pipe, types)))
    cn = design_cost(
        pipe, types,
        datapaths=lowered_datapaths(lower(pipe, types, datapath="narrow")))
    assert cn.power_proxy < ce.power_proxy
    # defaults stay byte-identical to the historical model
    assert base.power_proxy == design_cost(pipe, types).power_proxy


def test_lower_rejects_unknown_datapath():
    pipe = usm.build()
    with pytest.raises(ValueError):
        lower(pipe, _types_for(pipe), datapath="int8")


# ---------------------------------------------------------------------------
# capability detection
# ---------------------------------------------------------------------------

def test_resolve_interpret_on_cpu_warns_once_and_interprets():
    from repro.lowering import pallas_backend as PB
    pipe = usm.build()
    lp = lower(pipe, _types_for(pipe), params=dict(usm.DEFAULT_PARAMS))
    PB._warned.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert PB.resolve_interpret(lp) is True      # no TPU/GPU here
        assert PB.resolve_interpret(lp) is True      # second call silent
    runtime = [x for x in w if issubclass(x.category, RuntimeWarning)]
    assert len(runtime) == 1
    assert "interpret mode" in str(runtime[0].message)


def test_needs_64bit_tracks_the_election():
    pipe, types = _wide_acc_pipe(taps=9)
    from repro.lowering.pallas_backend import needs_64bit
    assert needs_64bit(lower(pipe, types))            # int64 carrier
    # the narrow election moves the datapath into int32-pair + one
    # widening combine — still 64-bit (the combine), so no change here;
    # but a plain int32 pipeline needs none
    p2 = usm.build()
    t2 = _types_for(p2)
    lp2 = lower(p2, t2, params=dict(usm.DEFAULT_PARAMS))
    # usm has f64 expr stages -> needs 64-bit
    assert needs_64bit(lp2)


# ---------------------------------------------------------------------------
# stored containers at island boundaries
# ---------------------------------------------------------------------------

def test_island_descriptors_carry_stored_containers():
    """The fused kernel's stage descriptors (shared by the pallas and
    shard_map executors) carry `backends.store_dtype` — the legalized
    narrow container, not the MAC carrier."""
    from repro.lowering import backends as B
    from repro.lowering.pallas_backend import island_program
    pipe = dus.build_extended()
    lp = lower(pipe, _types_for(pipe))
    plan = partition_islands(lp, (48, 48))
    for isl in plan.islands:
        for d in island_program(lp, isl):
            want = np.dtype(B.store_dtype(lp.stages[d["name"]]))
            assert np.dtype(d["dtype"]) == want, d["name"]
            assert want.itemsize <= 2, \
                f"{d['name']}: dus_ext tiles must all fit 16-bit containers"


def test_boundary_buffers_stitch_narrow_and_save_bytes():
    """Multi-island stitching materializes HBM boundaries in the stored
    container: every dus boundary is sub-int32, `boundary_bytes` prices
    real savings vs the uniform int32 baseline, and `stored_mix` shows
    no int64/f64 leakage."""
    from repro.lowering.backends import store_dtype
    pipe = dus.build()
    types = _types_for(pipe)
    lp = lower(pipe, types)
    plan = partition_islands(lp, (47, 48))
    assert len(plan.islands) > 1
    for isl in plan.islands:
        for out in isl.outputs:
            assert np.dtype(store_dtype(lp.stages[out])).itemsize <= 2, out
        stored, saved = isl.boundary_bytes(lp)
        assert stored > 0 and saved > 0
        mix = isl.stored_mix(lp)
        assert "int64" not in mix and "float64" not in mix, mix
    # and the stitched execution over those narrow boundaries is exact
    img = _img((47, 48), seed=23)
    oracle = run_fixed(pipe, img, types)
    outs = compile_backend(lp, "pallas")(img)
    for stage in pipe.outputs:
        np.testing.assert_array_equal(np.asarray(oracle[stage]),
                                      outs[stage], err_msg=stage)


def test_boundary_bytes_accounts_f64_as_negative_savings():
    """A float-stored boundary costs 8 B/px: `boundary_bytes` must report
    it as negative savings, not silently fold it into the narrow wins."""
    pipe = dus.build_extended()
    types = _types_for(pipe)
    phase_types = {"resS": ((2, 1), {(0, 0): FixedPointType(8, 1, True)})}

    class FakePlan:
        def phase_types(self, column=None):
            return phase_types

        def types(self, column=None):
            return types

    lp = lower(pipe, FakePlan())
    assert lp.stages["resS"].store_float
    iplan = partition_islands(lp, (48, 48), outputs=["resS"])
    isl = next(i for i in iplan.islands if "resS" in i.outputs)
    stored, saved = isl.boundary_bytes(lp)
    h, w = isl.schedule.stages["resS"].H, isl.schedule.stages["resS"].W
    assert stored >= h * w * 8
    assert saved <= -h * w * 4      # 4 - 8 bytes per resS pixel, at least
