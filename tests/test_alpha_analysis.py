"""alpha-analysis reproduces the paper's static tables exactly."""
import math

import numpy as np
import pytest

from repro.core.range_analysis import analyze, alpha_table
from repro.dsl.exec import run_abstract, run_float
from repro.pipelines import dus, hcd, optical_flow, usm

# ---------------------------------------------------------------------------
# Table II — HCD ranges and alphas
# ---------------------------------------------------------------------------

TABLE_II = {
    "img": ((0, 255), 8),
    "Ix": ((-85, 85), 8),
    "Iy": ((-85, 85), 8),
    "Ixy": ((-85 ** 2, 85 ** 2), 14),
    "Ixx": ((0, 85 ** 2), 13),
    "Iyy": ((0, 85 ** 2), 13),
    "Sxy": ((-9 * 85 ** 2, 9 * 85 ** 2), 17),
    "Sxx": ((0, 9 * 85 ** 2), 16),
    "Syy": ((0, 9 * 85 ** 2), 16),
    "det": ((-(9 * 85 ** 2) ** 2, (9 * 85 ** 2) ** 2), 33),
    "trace": ((0, 2 * 9 * 85 ** 2), 17),
    "harris": ((-1.16 * (9 * 85 ** 2) ** 2, (9 * 85 ** 2) ** 2), 34),
}


def test_hcd_matches_table_2():
    res = analyze(hcd.build())
    for stage, ((lo, hi), alpha) in TABLE_II.items():
        r = res[stage]
        assert math.isclose(r.range.lo, lo, rel_tol=1e-9), (stage, r.range)
        assert math.isclose(r.range.hi, hi, rel_tol=1e-9), (stage, r.range)
        assert r.alpha == alpha, (stage, r.alpha, alpha)


def test_usm_matches_table_5_alpha():
    alphas = alpha_table(usm.build())
    assert alphas == {"img": 8, "blurx": 8, "blury": 8, "sharpen": 10,
                      "masked": 9}


def test_dus_matches_table_8_alpha():
    alphas = alpha_table(dus.build())
    assert all(a == 8 for a in alphas.values())


def test_of_static_alpha_blowup_profile_flat():
    """Table IX's qualitative claim: V-stage static alphas grow with depth."""
    p = optical_flow.build()
    res = analyze(p)
    vs = [res[f"Vx{k}"].alpha for k in range(1, 5)]
    assert vs == sorted(vs) and vs[-1] - vs[0] >= 12   # strong growth
    assert res["It"].alpha == 9
    assert res["Ix"].alpha == 8


# ---------------------------------------------------------------------------
# framework (§IV-C): per-pixel abstract execution agrees with combined analysis
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("builder", [hcd.build, usm.build, dus.build])
def test_perpixel_interval_within_combined(builder):
    p = builder()
    comb = analyze(p)
    per = run_abstract(p, (10, 10), "interval")
    for k in p.topo_order():
        assert comb[k].range.encloses(per[k]["range"]), k


@pytest.mark.parametrize("builder,shape", [(hcd.build, (10, 10)),
                                           (usm.build, (10, 10))])
def test_concrete_run_within_perpixel_analysis(builder, shape):
    """Soundness end-to-end: float exec results live inside analyzed ranges."""
    p = builder()
    rng = np.random.default_rng(0)
    img = rng.integers(0, 256, size=shape).astype(np.float64)
    env = run_float(p, img, {"weight": 1.0, "thresh": 10.0})
    comb = analyze(p)
    for k in p.topo_order():
        arr = np.asarray(env[k])
        assert comb[k].range.lo - 1e-6 <= arr.min(), k
        assert arr.max() <= comb[k].range.hi + 1e-6, k


def test_affine_domain_pluggable():
    """§IV-C: swapping the domain string is the whole integration effort."""
    p = hcd.build()
    ia = analyze(p, domain="interval")
    aa = analyze(p, domain="affine")
    # both sound: affine's interval hull must contain... no — both must
    # contain the true range; neither must be malformed.  For linear stages
    # they agree exactly.
    for stage in ("img", "Ix", "Iy", "trace"):
        assert math.isclose(aa[stage].range.lo, ia[stage].range.lo, rel_tol=1e-6)
        assert math.isclose(aa[stage].range.hi, ia[stage].range.hi, rel_tol=1e-6)
