"""Per-kernel tests: Pallas (interpret mode) vs pure-jnp oracles.

Integer paths assert exact equality; float epilogues use allclose.
Shapes/dtypes swept per the deliverable spec.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from _hyp_compat import given, settings, st

from repro.core.fixedpoint import FixedPointType
from repro.kernels.qdq import ops as qdq_ops
from repro.kernels.qdq.kernel import block_dequantize, block_quantize
from repro.kernels.qdq.ref import block_dequantize_ref, block_quantize_ref
from repro.kernels.qmatmul.kernel import qmatmul_dequant, qmatmul_i32
from repro.kernels.qmatmul.ops import matmul_quantized
from repro.kernels.qmatmul.ref import qmatmul_dequant_ref, qmatmul_i32_ref
from repro.kernels.stencil.kernel import fixedpoint_stencil
from repro.kernels.stencil.ops import quantize_weights, stencil_fixed
from repro.kernels.stencil.ref import fixedpoint_stencil_ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# stencil
# ---------------------------------------------------------------------------

SOBEL = [[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]]
BLUR = [[1, 4, 6, 4, 1]]
BOX = [[1, 1, 1], [1, 1, 1], [1, 1, 1]]


@pytest.mark.parametrize("H,W,tile_h", [(16, 16, 8), (24, 20, 8), (32, 8, 4),
                                        (8, 64, 8)])
@pytest.mark.parametrize("weights,scale", [(SOBEL, 1 / 12), (BLUR, 1 / 16),
                                           (BOX, 1.0)])
def test_stencil_kernel_exact_vs_ref(H, W, tile_h, weights, scale):
    img = RNG.integers(0, 256, (H, W)).astype(np.float32)
    taps, w_beta = quantize_weights(weights, scale)
    halo = max(max(abs(dy), abs(dx)) for dy, dx, _ in taps)
    t_in = FixedPointType(8, 0, signed=False)
    q = np.pad(img.astype(np.int32), halo, mode="edge")
    shift = w_beta
    got = fixedpoint_stencil(jnp.asarray(q), taps, halo, shift,
                             -(2 ** 15), 2 ** 15 - 1,
                             tile_h=min(tile_h, H), interpret=True)
    want = fixedpoint_stencil_ref(jnp.asarray(q), taps, halo, shift,
                                  -(2 ** 15), 2 ** 15 - 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("beta_in,beta_out", [(0, 0), (0, 4), (4, 4), (2, 6)])
def test_stencil_ops_close_to_float(beta_in, beta_out):
    img = RNG.integers(0, 256, (16, 16)).astype(np.float32)
    t_in = FixedPointType(8, beta_in, signed=False)
    t_out = FixedPointType(8, beta_out, signed=True)
    got = np.asarray(stencil_fixed(jnp.asarray(img), SOBEL, 1 / 12, t_in, t_out))
    # float reference stencil
    ref = np.zeros_like(img)
    pad = np.pad(img, 1, mode="edge")
    for dy in range(3):
        for dx in range(3):
            ref += SOBEL[dy][dx] * pad[dy:dy + 16, dx:dx + 16]
    ref /= 12
    ref = np.clip(ref, t_out.min_value, t_out.max_value)
    # error budget: output rounding + weight quantization (Sobel/12 is not
    # dyadic, so w_beta caps at 12 with |dw| <= 2^-13 per tap)
    taps, w_beta = quantize_weights(SOBEL, 1 / 12)
    werr = sum(abs(wq / 2 ** w_beta - w / 12)
               for (dy, dx, wq), w in zip(
                   taps, [w for row in SOBEL for w in row if w != 0]))
    bound = 2 ** -t_out.beta + 255.0 * werr + 1e-5
    assert np.max(np.abs(got - ref)) <= bound


def test_stencil_kernel_vs_ops_pallas_equals_ref_path():
    img = RNG.integers(0, 256, (24, 24)).astype(np.float32)
    t_in = FixedPointType(8, 2, signed=False)
    t_out = FixedPointType(9, 3, signed=True)
    a = np.asarray(stencil_fixed(jnp.asarray(img), BLUR, 1 / 16, t_in, t_out,
                                 use_ref=False))
    b = np.asarray(stencil_fixed(jnp.asarray(img), BLUR, 1 / 16, t_in, t_out,
                                 use_ref=True))
    np.testing.assert_array_equal(a, b)


def test_stencil_width_budget_guard():
    t_in = FixedPointType(30, 0, signed=True)
    with pytest.raises(ValueError, match="int32"):
        stencil_fixed(jnp.zeros((8, 8), jnp.float32), BOX, 1.0, t_in,
                      FixedPointType(31, 0))


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,block", [(128, 128, 128, 128),
                                         (256, 384, 128, 128),
                                         (64, 64, 64, 32),
                                         (32, 96, 64, 32)])
def test_qmatmul_i32_exact(M, K, N, block):
    a = RNG.integers(-128, 128, (M, K)).astype(np.int8)
    b = RNG.integers(-128, 128, (K, N)).astype(np.int8)
    got = qmatmul_i32(jnp.asarray(a), jnp.asarray(b), block, block, block)
    want = qmatmul_i32_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qmatmul_fused_dequant_matches_ref():
    M = K = N = 128
    a = RNG.integers(-128, 128, (M, K)).astype(np.int8)
    b = RNG.integers(-128, 128, (K, N)).astype(np.int8)
    sa = RNG.uniform(0.001, 0.1, (M, 1)).astype(np.float32)
    sb = RNG.uniform(0.001, 0.1, (1, N)).astype(np.float32)
    got = qmatmul_dequant(*map(jnp.asarray, (a, b, sa, sb)), block_m=64,
                          block_n=64, block_k=64)
    want = qmatmul_dequant_ref(*map(jnp.asarray, (a, b, sa, sb)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("M,K,N", [(64, 64, 64), (100, 72, 36), (16, 300, 48)])
def test_matmul_quantized_accuracy(M, K, N):
    """Quantized matmul approximates f32 within per-channel int8 error."""
    a = RNG.normal(size=(M, K)).astype(np.float32)
    b = RNG.normal(size=(K, N)).astype(np.float32)
    got = np.asarray(matmul_quantized(jnp.asarray(a), jnp.asarray(b), block=32))
    want = a @ b
    # int8 symmetric error bound: ~ (|a| |b| K) / 127 per element, loose 3x
    bound = 3 * np.abs(a).max() * np.abs(b).max() * K / 127
    assert np.max(np.abs(got - want)) < bound
    # and the pallas path equals the ref path bit-for-bit
    ref = np.asarray(matmul_quantized(jnp.asarray(a), jnp.asarray(b),
                                      use_ref=True))
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# qdq
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("NB,BS", [(8, 256), (5, 64), (16, 128), (1, 32)])
def test_block_quantize_matches_ref(NB, BS):
    x = RNG.normal(size=(NB, BS)).astype(np.float32) * 10
    q, s = block_quantize(jnp.asarray(x))
    qr, sr = block_quantize_ref(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    # interpret-mode reductions may differ from jnp by one ulp
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # identical inputs -> bit-identical dequant between kernel and oracle
    out = block_dequantize(q, s)
    outr = block_dequantize_ref(q, s)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(outr))


@given(st.integers(1, 4).map(lambda k: 2 ** k * 17),
       st.integers(0, 3))
@settings(max_examples=20)
def test_fake_quant_error_bound(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n,)).astype(np.float32)
    y = np.asarray(qdq_ops.fake_quant(jnp.asarray(x), block_size=64))
    # error per element <= scale/2 = absmax/254 per block of 64
    assert np.max(np.abs(x - y)) <= np.abs(x).max() / 127 + 1e-7
    assert y.shape == x.shape


def test_zero_block_no_nan():
    x = jnp.zeros((4, 64), jnp.float32)
    q, s = block_quantize(x)
    out = np.asarray(block_dequantize(q, s))
    assert np.all(out == 0) and not np.any(np.isnan(out))


def test_compress_decompress_roundtrip_shape():
    x = RNG.normal(size=(3, 7, 11)).astype(np.float32)
    q, s, pad = qdq_ops.compress(jnp.asarray(x), block_size=32)
    y = qdq_ops.decompress(q, s, pad, x.shape)
    assert y.shape == x.shape
    assert np.max(np.abs(np.asarray(y) - x)) <= np.abs(x).max() / 127 + 1e-7
